//! §Perf — hot-path microbenchmarks used by the optimization pass:
//!   (i)  FCS dense apply throughput (GB/s) vs a memcpy-style roofline,
//!   (ii) rank-R FFT path vs the R·J̃·log(J̃) flop model,
//!   (iii) RTPM t_iuu / t_uuu per-call latency,
//!   (iv) coordinator throughput / latency percentiles.
//! Results feed EXPERIMENTS.md §Perf (before/after per iteration).

use fcs::bench::{fmt_secs, measure, quick_mode, ResultSink, Table};
use fcs::coordinator::{Request, Service, ServiceConfig};
use fcs::fft::FftWorkspace;
use fcs::hash::ModeHashes;
use fcs::sketch::{FastCountSketch, FcsEstimator, TensorSketch};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;

fn main() {
    // Scrape hook (used by scripts/metrics_smoke.py): serve GET /metrics +
    // /healthz for the duration of the run when FCS_METRICS_ADDR is set,
    // then hold the process open FCS_METRICS_HOLD_SECS seconds so a scraper
    // can read the final counters.
    let exporter = std::env::var("FCS_METRICS_ADDR").ok().map(|addr| {
        let exp = fcs::obs::exporter::Exporter::bind(&addr).expect("bind FCS_METRICS_ADDR");
        eprintln!("[perf] serving /metrics on {}", exp.local_addr());
        exp
    });

    let reps = if quick_mode() { 5 } else { 20 };
    let mut table = Table::new("§Perf — hot paths", &["path", "metric", "value"]);
    let mut sink = ResultSink::new("perf_hotpath");

    // (i) dense FCS apply vs copy roofline
    {
        let dim = 200usize;
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&mut rng, &[dim, dim, dim]);
        let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], 4000);
        let fcs = FastCountSketch::new(mh);
        let mut out = vec![0.0; fcs.j_tilde];
        let s = measure(2, reps, || fcs.apply_dense_into(&t, &mut out));
        let bytes = t.numel() as f64 * 8.0;
        let gbps = bytes / s.median / 1e9;
        // copy roofline
        let mut dst = vec![0.0f64; t.numel()];
        let sc = measure(2, reps, || dst.copy_from_slice(&t.data));
        let roof = bytes / sc.median / 1e9;
        table.row(vec!["fcs dense apply (200³)".into(), "GB/s".into(), format!("{gbps:.2}")]);
        table.row(vec!["memcpy roofline".into(), "GB/s".into(), format!("{roof:.2}")]);
        table.row(vec!["fcs/memcpy".into(), "ratio".into(), format!("{:.2}", gbps / roof)]);
        sink.record(&[("path", "fcs_dense_apply".into()), ("gbps", gbps.into()), ("roof_gbps", roof.into())]);
    }

    // (ii) rank-R FFT path: spectral accumulation (one IFFT total, workspace
    // reuse, rank fan-out) vs the per-rank-IFFT baseline it replaced. The
    // acceptance gate for the spectral engine is ≥2× at R ≥ 16.
    {
        let dim = 100usize;
        let j = 4000usize;
        let mut rng = Rng::seed_from_u64(2);
        let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], j);
        let fcs = FastCountSketch::new(mh.clone());
        for rank in [10usize, 16, 32] {
            let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
            let s_new = measure(2, reps, || fcs.apply_cp(&cp));
            let s_base = measure(2, reps, || fcs.apply_cp_per_rank(&cp));
            // Serial zero-alloc workspace path (what the coordinator workers
            // and the ALS inner loop run).
            let mut ws = FftWorkspace::new();
            let mut out = Vec::new();
            let s_ws = measure(2, reps, || fcs.apply_cp_into(&cp, &mut ws, &mut out));
            let speedup = s_base.median / s_new.median;
            table.row(vec![
                format!("fcs rank-R spectral (J=4000,R={rank})"),
                "time".into(),
                fmt_secs(s_new.median),
            ]);
            table.row(vec![
                format!("fcs rank-R per-rank-IFFT baseline (R={rank})"),
                "time".into(),
                fmt_secs(s_base.median),
            ]);
            table.row(vec![
                format!("fcs rank-R workspace serial (R={rank})"),
                "time".into(),
                fmt_secs(s_ws.median),
            ]);
            table.row(vec![
                format!("fcs spectral vs baseline (R={rank})"),
                "speedup".into(),
                format!("{speedup:.2}x"),
            ]);
            sink.record(&[
                ("path", "fcs_rank_r_fft".into()),
                ("rank", (rank as f64).into()),
                ("secs_spectral", s_new.median.into()),
                ("secs_per_rank_baseline", s_base.median.into()),
                ("secs_workspace_serial", s_ws.median.into()),
                ("speedup", speedup.into()),
            ]);
        }
        let rank = 10usize;
        let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
        let ts = TensorSketch::new(mh);
        let s2 = measure(2, reps, || ts.apply_cp(&cp));
        let s2b = measure(2, reps, || ts.apply_cp_per_rank(&cp));
        table.row(vec!["ts rank-R spectral (same hashes, R=10)".into(), "time".into(), fmt_secs(s2.median)]);
        table.row(vec![
            "ts spectral vs per-rank baseline".into(),
            "speedup".into(),
            format!("{:.2}x", s2b.median / s2.median),
        ]);
        sink.record(&[
            ("path", "ts_rank_r_fft".into()),
            ("secs_spectral", s2.median.into()),
            ("secs_per_rank_baseline", s2b.median.into()),
        ]);
    }

    // (ii-b) FFT kernel microbenchmarks: the scalar radix-2 oracle vs the
    // split-plane radix-4 kernel looped one signal at a time vs one blocked
    // batched pass — per (length, batch), machine-readable (§Perf "fft
    // kernel" rows). Also the real-transform primitive the spectral paths
    // call: fft_real_many_into (one call, all lanes) vs a loop of
    // fft_real_into (the PR 3 per-spectrum dispatch it replaced).
    {
        use fcs::fft::{
            fft_real_into, fft_real_many_into, C64, Dir, FftScratch, Plan, ScalarRadix2Plan,
        };
        let mut rng = Rng::seed_from_u64(5);
        let batch = 16usize;
        for &n in &[1024usize, 4096, 16384] {
            let plan = Plan::new(n);
            let oracle = ScalarRadix2Plan::new(n);
            let mut scratch = FftScratch::new();
            let sig: Vec<C64> =
                (0..n * batch).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let mut inter = sig.clone();
            let s_scalar = measure(1, reps, || {
                for b in 0..batch {
                    oracle.process(&mut inter[b * n..(b + 1) * n], Dir::Forward);
                }
            });
            // split-plane kernel, one signal per call (signal-major == a
            // single lane-major lane)
            let mut re: Vec<f64> = sig.iter().map(|z| z.re).collect();
            let mut im: Vec<f64> = sig.iter().map(|z| z.im).collect();
            let s_looped = measure(1, reps, || {
                for b in 0..batch {
                    plan.process_many(
                        &mut re[b * n..(b + 1) * n],
                        &mut im[b * n..(b + 1) * n],
                        1,
                        Dir::Forward,
                        &mut scratch,
                    );
                }
            });
            // one blocked pass, batch innermost (lane-major planes)
            let mut bre = vec![0.0; n * batch];
            let mut bim = vec![0.0; n * batch];
            for (i, z) in sig.iter().enumerate() {
                let (k, b) = (i % n, i / n);
                bre[k * batch + b] = z.re;
                bim[k * batch + b] = z.im;
            }
            let s_batched = measure(1, reps, || {
                plan.process_many(&mut bre, &mut bim, batch, Dir::Forward, &mut scratch);
            });
            table.row(vec![
                format!("fft n={n} scalar radix-2 ×{batch}"),
                "time".into(),
                fmt_secs(s_scalar.median),
            ]);
            table.row(vec![
                format!("fft n={n} split-plane looped ×{batch}"),
                "time".into(),
                fmt_secs(s_looped.median),
            ]);
            table.row(vec![
                format!("fft n={n} split-plane batched (B={batch})"),
                "time".into(),
                fmt_secs(s_batched.median),
            ]);
            table.row(vec![
                format!("fft n={n} batched vs scalar"),
                "speedup".into(),
                format!("{:.2}x", s_scalar.median / s_batched.median),
            ]);
            sink.record(&[
                ("path", "fft_kernel".into()),
                ("n", (n as f64).into()),
                ("batch", (batch as f64).into()),
                ("secs_scalar_radix2", s_scalar.median.into()),
                ("secs_split_radix_looped", s_looped.median.into()),
                ("secs_split_radix_batched", s_batched.median.into()),
                ("speedup_batched_vs_scalar", (s_scalar.median / s_batched.median).into()),
                ("speedup_batched_vs_looped", (s_looped.median / s_batched.median).into()),
            ]);
        }
        // Real-transform primitive at the rank-R spectral shape (stride = a
        // J̃-scale signal, n = next_pow2): one batched call vs a per-spectrum
        // loop — the exact dispatch pattern accumulate_cp_spectra replaced.
        {
            let stride = 11998usize;
            let n = 16384usize;
            let lanes = 12usize; // e.g. 4 CP ranks × 3 modes per chunk
            let xs: Vec<f64> = (0..stride * lanes).map(|_| rng.normal()).collect();
            let mut ws = FftWorkspace::new();
            let (mut sre, mut sim) = (Vec::new(), Vec::new());
            let s_many = measure(1, reps, || {
                fft_real_many_into(&xs, stride, lanes, n, &mut ws, &mut sre, &mut sim);
            });
            let mut spec = Vec::new();
            let s_loop = measure(1, reps, || {
                for b in 0..lanes {
                    fft_real_into(&xs[b * stride..(b + 1) * stride], n, &mut ws, &mut spec);
                }
            });
            table.row(vec![
                format!("rfft n={n} ×{lanes} batched vs per-spectrum"),
                "speedup".into(),
                format!("{:.2}x", s_loop.median / s_many.median),
            ]);
            sink.record(&[
                ("path", "rfft_many".into()),
                ("n", (n as f64).into()),
                ("lanes", (lanes as f64).into()),
                ("secs_batched", s_many.median.into()),
                ("secs_per_spectrum_loop", s_loop.median.into()),
                ("speedup", (s_loop.median / s_many.median).into()),
            ]);
        }
    }

    // (ii-c) SpectralDriver cross-repetition batching: the estimator's
    // serial t_mode (ONE driver pass over all D repetitions — chunk-shared
    // forwards/inverses) vs the per-repetition loop of single-group
    // correlate-and-gather calls it collapsed (the shape of the deleted
    // duplicated chunk scaffolding, and what the rayon-less parallel path
    // runs per thread). §Perf "t_mode_driver" rows.
    {
        use fcs::sketch::{elementwise_median, ContractionEstimator};
        let dim = 100usize;
        let j = 4000usize;
        let d_reps = 5usize;
        let mut rng = Rng::seed_from_u64(6);
        let t = Tensor::randn(&mut rng, &[dim, dim, dim]);
        let hashes: Vec<ModeHashes> = (0..d_reps)
            .map(|_| ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], j))
            .collect();
        let est = FcsEstimator::build_with_hashes(&t, &hashes);
        let ops: Vec<FastCountSketch> =
            hashes.iter().map(|h| FastCountSketch::new(h.clone())).collect();
        let rep_ffts: Vec<Vec<fcs::fft::C64>> = ops
            .iter()
            .map(|op| op.core().sketch_spectrum(&op.apply_dense(&t)))
            .collect();
        let u = rng.normal_vec(dim);
        let v = rng.normal_vec(dim);
        let w = rng.normal_vec(dim);
        let vs: [&[f64]; 3] = [&u, &v, &w];
        let mut out = Vec::new();
        let s_driver = measure(2, reps, || est.t_mode_into(0, &vs, &mut out));
        let mut ws = FftWorkspace::new();
        let mut rows: Vec<Vec<f64>> = vec![Vec::new(); d_reps];
        let s_loop = measure(2, reps, || {
            for ((op, st_fft), row) in ops.iter().zip(&rep_ffts).zip(rows.iter_mut()) {
                op.core().correlate_gather_into(st_fft, 0, &vs, &mut ws, row);
            }
            let _ = elementwise_median(&rows);
        });
        let speedup = s_loop.median / s_driver.median;
        table.row(vec![
            format!("t_mode driver batched (D={d_reps},J={j})"),
            "time".into(),
            fmt_secs(s_driver.median),
        ]);
        table.row(vec![
            format!("t_mode per-rep loop (D={d_reps},J={j})"),
            "time".into(),
            fmt_secs(s_loop.median),
        ]);
        table.row(vec![
            "t_mode driver vs per-rep loop".into(),
            "speedup".into(),
            format!("{speedup:.2}x"),
        ]);
        sink.record(&[
            ("path", "t_mode_driver".into()),
            ("d_reps", (d_reps as f64).into()),
            ("j", (j as f64).into()),
            ("secs_batched_serial", s_driver.median.into()),
            ("secs_per_rep_loop", s_loop.median.into()),
            ("speedup", speedup.into()),
        ]);
    }

    // (iii) estimator query latency
    {
        let dim = 100usize;
        let j = 5000usize;
        let mut rng = Rng::seed_from_u64(3);
        let cp = CpTensor::random_orthogonal_symmetric(&mut rng, dim, 10, 3);
        let mut t = cp.to_dense();
        t.add_noise(&mut rng, 0.01);
        use fcs::sketch::ContractionEstimator;
        let est = FcsEstimator::build(&t, 2, j, &mut rng);
        let mut u = rng.normal_vec(dim);
        fcs::linalg::normalize(&mut u);
        let s_iuu = measure(2, reps, || est.t_iuu(&u));
        let s_uuu = measure(2, reps, || est.t_uuu(&u));
        table.row(vec!["fcs t_iuu (I=100,J=5000,D=2)".into(), "time".into(), fmt_secs(s_iuu.median)]);
        table.row(vec!["fcs t_uuu".into(), "time".into(), fmt_secs(s_uuu.median)]);
        sink.record(&[
            ("path", "estimator_query".into()),
            ("t_iuu_secs", s_iuu.median.into()),
            ("t_uuu_secs", s_uuu.median.into()),
        ]);
    }

    // (iv) coordinator throughput/latency (pure-Rust path + XLA if present)
    for (label, runtime) in [
        ("coordinator(rust)", None),
        ("coordinator(xla)", fcs::runtime::spawn_runtime(None).ok()),
    ] {
        if label.contains("xla") && runtime.is_none() {
            eprintln!("[perf] skipping XLA coordinator (no artifacts)");
            continue;
        }
        let svc = Service::start(ServiceConfig::default(), runtime).unwrap();
        let h = svc.handle();
        let n = if quick_mode() { 200 } else { 2000 };
        let mut rng = Rng::seed_from_u64(4);
        let reqs: Vec<Vec<f64>> = (0..64).map(|_| rng.normal_vec(h.cs_in_dim)).collect();
        let sw = fcs::util::timing::Stopwatch::start();
        let mut pend = Vec::new();
        for i in 0..n {
            loop {
                match h.submit(Request::CsVec { x: reqs[i % reqs.len()].clone() }) {
                    Ok(rx) => {
                        pend.push(rx);
                        break;
                    }
                    Err(fcs::coordinator::ServiceError::Busy) => {
                        // drain a little
                        if let Some(rx) = pend.pop() {
                            let _ = rx.recv();
                        }
                    }
                    Err(e) => panic!("{e}"),
                }
            }
        }
        for rx in pend {
            let _ = rx.recv();
        }
        let secs = sw.elapsed_secs();
        let report = svc.stats();
        let cs = report.per_op.iter().find(|o| o.op == "cs_vec").unwrap();
        table.row(vec![label.into(), "req/s".into(), format!("{:.0}", n as f64 / secs)]);
        table.row(vec![label.into(), "p50/p95/p99 µs".into(),
            format!("{:.0}/{:.0}/{:.0}", cs.p50_us, cs.p95_us, cs.p99_us)]);
        table.row(vec![label.into(), "mean batch fill".into(), format!("{:.1}", report.mean_batch_fill)]);
        sink.record(&[
            ("path", label.into()),
            ("rps", (n as f64 / secs).into()),
            ("p50_us", cs.p50_us.into()),
            ("p99_us", cs.p99_us.into()),
            ("mean_batch_fill", report.mean_batch_fill.into()),
        ]);
        svc.shutdown();
    }

    // (iv-b) coordinator fused-flight throughput: same-class SketchCp floods
    // against a single worker at several burst widths. Width-1 bursts are the
    // serial baseline (every job its own width-1 flight); wider bursts let
    // the saturated drain-and-fuse path pack cross-request flights (capped
    // by the WORKER_DRAIN batch bound). §Perf "coord_flood" rows: `secs` is
    // the trend-gated timing, `width` its qualifier; the flight-width
    // histogram (mean/max from the per-width stats) verifies the fused path
    // actually engaged rather than silently degenerating to serial.
    {
        let n_jobs = if quick_mode() { 64 } else { 512 };
        let mut rng = Rng::seed_from_u64(7);
        let cp = CpTensor::randn(&mut rng, &[10, 10, 10], 2);
        let j = 32usize;
        for width in [1usize, 4, 16, 64] {
            let svc = Service::start(
                ServiceConfig {
                    workers: 1,
                    queue_capacity: 256,
                    batch_deadline: std::time::Duration::from_micros(200),
                    seed: 9,
                },
                None,
            )
            .unwrap();
            let h = svc.handle();
            let sw = fcs::util::timing::Stopwatch::start();
            let mut done = 0usize;
            while done < n_jobs {
                let burst = width.min(n_jobs - done);
                let mut rxs = Vec::with_capacity(burst);
                for _ in 0..burst {
                    loop {
                        match h.submit(Request::SketchCp { cp: cp.clone(), j }) {
                            Ok(rx) => {
                                rxs.push(rx);
                                break;
                            }
                            Err(fcs::coordinator::ServiceError::Busy) => std::thread::yield_now(),
                            Err(e) => panic!("{e}"),
                        }
                    }
                }
                for rx in rxs {
                    rx.recv().unwrap().unwrap();
                }
                done += burst;
            }
            let secs = sw.elapsed_secs();
            let report = svc.stats();
            let (mut flights, mut jobs, mut max_w) = (0u64, 0u64, 0usize);
            for f in &report.flights {
                flights += f.flights;
                jobs += f.jobs;
                max_w = max_w.max(f.width);
            }
            let mean_w = if flights > 0 { jobs as f64 / flights as f64 } else { 0.0 };
            table.row(vec![
                format!("coord flood sketch_cp (burst={width})"),
                "jobs/s".into(),
                format!("{:.0}", n_jobs as f64 / secs),
            ]);
            table.row(vec![
                format!("coord flood flight width (burst={width})"),
                "mean/max".into(),
                format!("{mean_w:.2}/{max_w}"),
            ]);
            sink.record(&[
                ("path", "coord_flood".into()),
                ("width", (width as f64).into()),
                ("n", (n_jobs as f64).into()),
                ("secs", secs.into()),
                ("jobs_per_sec", (n_jobs as f64 / secs).into()),
                ("mean_flight_width", mean_w.into()),
                ("max_flight_width", (max_w as f64).into()),
            ]);
            svc.shutdown();
        }
    }

    // (v) sharded merge: per-slab scatter + pairwise tree reduce vs one
    // whole-tensor sketch, at several shard counts. The scatter work is
    // identical either way (O(nnz) total across slabs); the merge adds
    // (k−1)·J̃ flops over ⌈log₂ k⌉ levels — the flop model EXPERIMENTS.md
    // §Sharded merge records. §Perf "shard_merge" rows: `shards` is the
    // trend qualifier, `secs_merge_only` isolates the reduce cost.
    {
        use fcs::sketch::ShardSketch;
        let dim = 64usize;
        let j = 4000usize;
        let shape = [dim, dim, dim];
        let mut rng = Rng::seed_from_u64(8);
        let t = Tensor::randn(&mut rng, &shape);
        let s_whole = measure(2, reps, || {
            let mut sh = ShardSketch::for_group(9, 0, &shape, j, false);
            sh.absorb_slab(&t.data, 0);
            sh
        });
        table.row(vec![
            format!("shard whole-tensor sketch (64³, J̃≈{})", 3 * j - 2),
            "time".into(),
            fmt_secs(s_whole.median),
        ]);
        for shards in [2usize, 4, 8, 16] {
            let chunk = t.data.len().div_ceil(shards);
            let cuts: Vec<usize> =
                (0..=shards).map(|i| (i * chunk).min(t.data.len())).collect();
            let s_sharded = measure(2, reps, || {
                let parts: Vec<ShardSketch> = cuts
                    .windows(2)
                    .map(|w| {
                        let mut sh = ShardSketch::for_group(9, 0, &shape, j, false);
                        sh.absorb_slab(&t.data[w[0]..w[1]], w[0]);
                        sh
                    })
                    .collect();
                ShardSketch::tree_merge(parts)
            });
            // Merge-only: pre-sketched parts, reduce over raw vectors (the
            // coordinator's MergeShards body).
            let parts: Vec<Vec<f64>> = cuts
                .windows(2)
                .map(|w| {
                    let mut sh = ShardSketch::for_group(9, 0, &shape, j, false);
                    sh.absorb_slab(&t.data[w[0]..w[1]], w[0]);
                    sh.into_sketch()
                })
                .collect();
            let s_merge = measure(2, reps, || fcs::sketch::tree_reduce_parts(&parts));
            table.row(vec![
                format!("shard sketch+merge (k={shards})"),
                "time".into(),
                fmt_secs(s_sharded.median),
            ]);
            table.row(vec![
                format!("shard merge only (k={shards})"),
                "time".into(),
                fmt_secs(s_merge.median),
            ]);
            table.row(vec![
                format!("shard overhead vs whole (k={shards})"),
                "ratio".into(),
                format!("{:.2}", s_sharded.median / s_whole.median),
            ]);
            sink.record(&[
                ("path", "shard_merge".into()),
                ("shards", (shards as f64).into()),
                ("j", (j as f64).into()),
                ("secs_whole", s_whole.median.into()),
                ("secs_sharded", s_sharded.median.into()),
                ("secs_merge_only", s_merge.median.into()),
                ("overhead_vs_whole", (s_sharded.median / s_whole.median).into()),
            ]);
        }
    }

    table.print();
    sink.flush();

    if let Some(mut exp) = exporter {
        let hold: u64 = std::env::var("FCS_METRICS_HOLD_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        if hold > 0 {
            eprintln!("[perf] holding /metrics open for {hold}s");
            std::thread::sleep(std::time::Duration::from_secs(hold));
        }
        exp.shutdown();
    }
}
