//! §Perf — hot-path microbenchmarks used by the optimization pass:
//!   (i)  FCS dense apply throughput (GB/s) vs a memcpy-style roofline,
//!   (ii) rank-R FFT path vs the R·J̃·log(J̃) flop model,
//!   (iii) RTPM t_iuu / t_uuu per-call latency,
//!   (iv) coordinator throughput / latency percentiles.
//! Results feed EXPERIMENTS.md §Perf (before/after per iteration).

use fcs::bench::{fmt_secs, measure, quick_mode, ResultSink, Table};
use fcs::coordinator::{Request, Service, ServiceConfig};
use fcs::hash::ModeHashes;
use fcs::sketch::{FastCountSketch, FcsEstimator, TensorSketch};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;

fn main() {
    let reps = if quick_mode() { 5 } else { 20 };
    let mut table = Table::new("§Perf — hot paths", &["path", "metric", "value"]);
    let mut sink = ResultSink::new("perf_hotpath");

    // (i) dense FCS apply vs copy roofline
    {
        let dim = 200usize;
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&mut rng, &[dim, dim, dim]);
        let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], 4000);
        let fcs = FastCountSketch::new(mh);
        let mut out = vec![0.0; fcs.j_tilde];
        let s = measure(2, reps, || fcs.apply_dense_into(&t, &mut out));
        let bytes = t.numel() as f64 * 8.0;
        let gbps = bytes / s.median / 1e9;
        // copy roofline
        let mut dst = vec![0.0f64; t.numel()];
        let sc = measure(2, reps, || dst.copy_from_slice(&t.data));
        let roof = bytes / sc.median / 1e9;
        table.row(vec!["fcs dense apply (200³)".into(), "GB/s".into(), format!("{gbps:.2}")]);
        table.row(vec!["memcpy roofline".into(), "GB/s".into(), format!("{roof:.2}")]);
        table.row(vec!["fcs/memcpy".into(), "ratio".into(), format!("{:.2}", gbps / roof)]);
        sink.record(&[("path", "fcs_dense_apply".into()), ("gbps", gbps.into()), ("roof_gbps", roof.into())]);
    }

    // (ii) rank-R FFT path
    {
        let dim = 100usize;
        let rank = 10usize;
        let j = 4000usize;
        let mut rng = Rng::seed_from_u64(2);
        let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
        let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], j);
        let fcs = FastCountSketch::new(mh.clone());
        let s = measure(2, reps, || fcs.apply_cp(&cp));
        let jt = (3 * j - 2) as f64;
        let flops = rank as f64 * 5.0 * jt * jt.log2() * 2.0; // ~2 fwd+1 inv per rank via pairwise
        table.row(vec!["fcs rank-R FFT (J=4000,R=10)".into(), "time".into(), fmt_secs(s.median)]);
        table.row(vec![
            "fcs rank-R FFT".into(),
            "GFLOP/s (5N log N model)".into(),
            format!("{:.2}", flops / s.median / 1e9),
        ]);
        let ts = TensorSketch::new(mh);
        let s2 = measure(2, reps, || ts.apply_cp(&cp));
        table.row(vec!["ts rank-R FFT (same hashes)".into(), "time".into(), fmt_secs(s2.median)]);
        sink.record(&[
            ("path", "fcs_rank_r_fft".into()),
            ("secs", s.median.into()),
            ("ts_secs", s2.median.into()),
        ]);
    }

    // (iii) estimator query latency
    {
        let dim = 100usize;
        let j = 5000usize;
        let mut rng = Rng::seed_from_u64(3);
        let cp = CpTensor::random_orthogonal_symmetric(&mut rng, dim, 10, 3);
        let mut t = cp.to_dense();
        t.add_noise(&mut rng, 0.01);
        use fcs::sketch::ContractionEstimator;
        let est = FcsEstimator::build(&t, 2, j, &mut rng);
        let mut u = rng.normal_vec(dim);
        fcs::linalg::normalize(&mut u);
        let s_iuu = measure(2, reps, || est.t_iuu(&u));
        let s_uuu = measure(2, reps, || est.t_uuu(&u));
        table.row(vec!["fcs t_iuu (I=100,J=5000,D=2)".into(), "time".into(), fmt_secs(s_iuu.median)]);
        table.row(vec!["fcs t_uuu".into(), "time".into(), fmt_secs(s_uuu.median)]);
        sink.record(&[
            ("path", "estimator_query".into()),
            ("t_iuu_secs", s_iuu.median.into()),
            ("t_uuu_secs", s_uuu.median.into()),
        ]);
    }

    // (iv) coordinator throughput/latency (pure-Rust path + XLA if present)
    for (label, runtime) in [
        ("coordinator(rust)", None),
        ("coordinator(xla)", fcs::runtime::spawn_runtime(None).ok()),
    ] {
        if label.contains("xla") && runtime.is_none() {
            eprintln!("[perf] skipping XLA coordinator (no artifacts)");
            continue;
        }
        let svc = Service::start(ServiceConfig::default(), runtime).unwrap();
        let h = svc.handle();
        let n = if quick_mode() { 200 } else { 2000 };
        let mut rng = Rng::seed_from_u64(4);
        let reqs: Vec<Vec<f64>> = (0..64).map(|_| rng.normal_vec(h.cs_in_dim)).collect();
        let sw = fcs::util::timing::Stopwatch::start();
        let mut pend = Vec::new();
        for i in 0..n {
            loop {
                match h.submit(Request::CsVec { x: reqs[i % reqs.len()].clone() }) {
                    Ok(rx) => {
                        pend.push(rx);
                        break;
                    }
                    Err(fcs::coordinator::ServiceError::Busy) => {
                        // drain a little
                        if let Some(rx) = pend.pop() {
                            let _ = rx.recv();
                        }
                    }
                    Err(e) => panic!("{e}"),
                }
            }
        }
        for rx in pend {
            let _ = rx.recv();
        }
        let secs = sw.elapsed_secs();
        let report = svc.stats();
        let cs = report.per_op.iter().find(|o| o.op == "cs_vec").unwrap();
        table.row(vec![label.into(), "req/s".into(), format!("{:.0}", n as f64 / secs)]);
        table.row(vec![label.into(), "p50/p95/p99 µs".into(),
            format!("{:.0}/{:.0}/{:.0}", cs.p50_us, cs.p95_us, cs.p99_us)]);
        table.row(vec![label.into(), "mean batch fill".into(), format!("{:.1}", report.mean_batch_fill)]);
        sink.record(&[
            ("path", label.into()),
            ("rps", (n as f64 / secs).into()),
            ("p50_us", cs.p50_us.into()),
            ("p99_us", cs.p99_us.into()),
            ("mean_batch_fill", report.mean_batch_fill.into()),
        ]);
        svc.shutdown();
    }

    table.print();
    sink.flush();
}
