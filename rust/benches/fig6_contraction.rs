//! Fig. 6 — tensor contraction compression: `A ∈ R^{30×40×50} ⊙₃,₁
//! B ∈ R^{50×40×30}`, entries U[0, 10], D = 20. Same four panels as Fig. 5.

use fcs::bench::{fmt_secs, quick_mode, ResultSink, Table};
use fcs::compress::{Codec, ContractCodec};
use fcs::tensor::Tensor;
use fcs::util::prng::Rng;

fn main() {
    let d = 20usize;
    let crs: Vec<f64> = if quick_mode() {
        vec![2.0, 8.0]
    } else {
        vec![1.0, 2.0, 4.0, 8.0, 16.0]
    };
    let mut rng = Rng::seed_from_u64(0xF166);
    let a = Tensor::rand_uniform(&mut rng, &[30, 40, 50], 0.0, 10.0);
    let b = Tensor::rand_uniform(&mut rng, &[50, 40, 30], 0.0, 10.0);

    let mut table = Table::new(
        "Fig. 6 — contraction compression (A 30×40×50 ⊙ B 50×40×30, D=20)",
        &["CR", "codec", "compress", "decompress", "rel_error", "hash_mem(KB)"],
    );
    let mut sink = ResultSink::new("fig6_contraction");

    for &cr in &crs {
        for codec in [Codec::Cs, Codec::Hcs, Codec::Fcs] {
            let stats = ContractCodec::evaluate(codec, &a, &b, cr, d, &mut rng);
            table.row(vec![
                format!("{cr:.0}"),
                stats.codec.into(),
                fmt_secs(stats.compress_secs),
                fmt_secs(stats.decompress_secs),
                format!("{:.4}", stats.rel_error),
                format!("{:.1}", stats.hash_bytes as f64 / 1024.0),
            ]);
            sink.record(&[
                ("cr", cr.into()),
                ("codec", stats.codec.into()),
                ("compress_secs", stats.compress_secs.into()),
                ("decompress_secs", stats.decompress_secs.into()),
                ("rel_error", stats.rel_error.into()),
                ("hash_bytes", stats.hash_bytes.into()),
            ]);
        }
        eprintln!("[fig6] CR={cr} done");
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: at small CR, FCS compresses faster than CS (which\n\
         must materialize the contraction), decompresses faster than HCS, and\n\
         is more accurate than HCS; FCS hash memory ≈ 5% of CS."
    );
}
