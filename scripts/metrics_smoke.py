#!/usr/bin/env python3
"""Smoke-test the /metrics exporter against a live quick-mode bench run.

Launches `cargo bench --bench perf_hotpath` with `FCS_METRICS_ADDR` pointed
at a free localhost port (the bench binds the exporter at startup and holds
the process open `FCS_METRICS_HOLD_SECS` after the run), waits for
`GET /healthz`, then polls `GET /metrics` until every required series is
present and nonzero:

* `fcs_plan_cache_hits_total{cache=...}` — plan-cache instrumentation;
* `fcs_flight_width_bucket{le="+Inf"}`  — coordinator flight histogram;
* `fcs_stage_ns_count{stage=...}`       — sampled SpectralDriver stage timers;
* `fcs_requests_completed_total{op="sketch_cp"}` — per-op request counters.

Exit 0 when all series go live before the bench exits; exit 1 otherwise.
The bench is its own process group so cleanup kills the whole cargo tree.

Usage:
    scripts/metrics_smoke.py [--timeout 900] [--hold 20]
"""

from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import time
import urllib.error
import urllib.request

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def http_get(url: str, timeout: float = 2.0) -> str | None:
    try:
        with urllib.request.urlopen(url, timeout=timeout) as r:
            return r.read().decode("utf-8", "replace")
    except (urllib.error.URLError, OSError):
        return None


def parse_series(body: str) -> dict[str, float]:
    """Exposition text -> {series-with-labels: value}."""
    out: dict[str, float] = {}
    for line in body.splitlines():
        if not line or line.startswith("#"):
            continue
        name, _, val = line.rpartition(" ")
        try:
            out[name] = float(val)
        except ValueError:
            continue
    return out


def check(vals: dict[str, float]) -> dict[str, bool]:
    def any_nonzero(prefix: str) -> bool:
        return any(v > 0 for k, v in vals.items() if k.startswith(prefix))

    return {
        "plan-cache hits": any_nonzero("fcs_plan_cache_hits_total"),
        "flight-width histogram": vals.get('fcs_flight_width_bucket{le="+Inf"}', 0) > 0,
        "stage timers": any_nonzero("fcs_stage_ns_count"),
        "sketch_cp completions": vals.get('fcs_requests_completed_total{op="sketch_cp"}', 0) > 0,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="overall budget in seconds (includes cargo compile)")
    ap.add_argument("--hold", type=int, default=20,
                    help="FCS_METRICS_HOLD_SECS passed to the bench")
    args = ap.parse_args()

    port = free_port()
    base = f"http://127.0.0.1:{port}"
    env = dict(os.environ)
    env.update({
        "FCS_BENCH_QUICK": "1",
        "FCS_METRICS_ADDR": f"127.0.0.1:{port}",
        "FCS_METRICS_HOLD_SECS": str(args.hold),
    })

    print(f"[metrics-smoke] launching quick bench, exporter on {base}")
    proc = subprocess.Popen(
        ["cargo", "bench", "--bench", "perf_hotpath"],
        cwd=REPO_ROOT,
        env=env,
        start_new_session=True,  # own process group: killpg reaps cargo + bench
    )

    deadline = time.monotonic() + args.timeout
    status: dict[str, bool] = {}
    ok = False
    try:
        while time.monotonic() < deadline:
            if http_get(f"{base}/healthz") is not None:
                break
            if proc.poll() is not None:
                print(f"[metrics-smoke] bench exited (rc={proc.returncode}) "
                      "before the exporter came up", file=sys.stderr)
                return 1
            time.sleep(1.0)
        else:
            print("[metrics-smoke] timed out waiting for /healthz", file=sys.stderr)
            return 1
        print("[metrics-smoke] /healthz is up; polling /metrics")

        while time.monotonic() < deadline:
            body = http_get(f"{base}/metrics")
            if body is not None:
                status = check(parse_series(body))
                if all(status.values()):
                    ok = True
                    break
            if proc.poll() is not None:
                # Process gone (hold window elapsed): last scrape decides.
                break
            time.sleep(2.0)
    finally:
        try:
            os.killpg(proc.pid, signal.SIGTERM)
        except (ProcessLookupError, PermissionError):
            pass
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            try:
                os.killpg(proc.pid, signal.SIGKILL)
            except (ProcessLookupError, PermissionError):
                pass

    for name, good in status.items():
        print(f"[metrics-smoke]   {'ok  ' if good else 'MISS'} {name}")
    if ok:
        print("[metrics-smoke] OK: all required series are live")
        return 0
    print("[metrics-smoke] FAILED: required series missing or zero", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
