#!/usr/bin/env python3
"""Invariant linter: fail CI when the crate's documented contracts drift.

The fcs serving stack carries several cross-file invariants that the Rust
compiler cannot see — stable metric names promised to dashboards, failpoint
site labels, the coordinator op tables, and the PR 10 atomic-ordering audit.
This linter parses the sources and docs statically and exits nonzero on any
drift:

  R1  metrics-code-to-doc   every metric family registered in
                            rust/src/obs/mod.rs appears in an EXPERIMENTS.md
                            stable-name table row
  R2  metrics-doc-to-code   every `fcs_*` family named in an EXPERIMENTS.md
                            table row is registered in code
  R3  fault-sites           `obs::FAULT_SITES` labels and the string
                            literals at `crate::fault::act(..)` /
                            `crate::fault::check(..)` call sites match in
                            both directions (catch-all "other" excepted)
  R4  request-variants      every `Request` enum variant is covered by
                            `op_name` with an op string from `obs::OPS`,
                            every non-catch-all op is produced by some
                            variant, and `fuses_with` stays exhaustive
                            (wildcard arm present)
  R5  ordering-comments     every `Ordering::` use site in rust/src and
                            rust/tests carries a `// ordering:`
                            justification comment on the same line or
                            within the preceding few lines
  R6  forbid-unsafe         rust/src/lib.rs keeps `#![forbid(unsafe_code)]`
                            and `#![deny(unreachable_pub)]`

`--self-test` copies the tree, injects one drift of each class, and asserts
the linter catches every one (and still passes on the pristine copy).
"""

from __future__ import annotations

import argparse
import re
import shutil
import sys
import tempfile
from pathlib import Path

# Lines above an `Ordering::` site searched for a `// ordering:` comment
# (covers a block comment shared by a handful of adjacent loads).
ORDERING_COMMENT_WINDOW = 12

# Vendored crates are third-party facades, not audited serving code.
EXCLUDED_PARTS = {"vendor", "target"}


def rust_files(root: Path) -> list[Path]:
    files = []
    for base in (root / "rust" / "src", root / "rust" / "tests"):
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.rs")):
            if EXCLUDED_PARTS.isdisjoint(p.parts):
                files.append(p)
    return files


def parse_registered_metrics(obs_mod: str) -> set[str]:
    """Family names from reg.counter/gauge/histogram(...) registration calls.

    The first string literal after the call token is the family name; it may
    sit on the following line (rustfmt wraps long calls).
    """
    names = set()
    for m in re.finditer(r"reg\s*\.\s*(?:counter|gauge|histogram)\s*\(", obs_mod):
        tail = obs_mod[m.end() : m.end() + 200]
        lit = re.search(r'"([^"]+)"', tail)
        if lit:
            names.add(lit.group(1))
    return names


def parse_doc_table_metrics(experiments: str) -> set[str]:
    """`fcs_*` families named in markdown table rows of EXPERIMENTS.md."""
    names = set()
    for line in experiments.splitlines():
        if not line.lstrip().startswith("|"):
            continue
        for m in re.finditer(r"`(fcs_[a-z0-9_]+)`", line):
            names.add(m.group(1))
    return names


def parse_str_array(source: str, array_name: str) -> list[str]:
    """String literals of a `pub const NAME: [&str; N] = [ ... ];` block."""
    m = re.search(
        rf"const\s+{array_name}\s*:\s*\[&str;\s*\d+\]\s*=\s*\[(.*?)\];",
        source,
        re.DOTALL,
    )
    if not m:
        return []
    return re.findall(r'"([^"]+)"', m.group(1))


def parse_fault_call_sites(root: Path) -> dict[str, list[str]]:
    """site-label -> [file:line] for qualified fault::act / fault::check calls.

    Only path-qualified calls count: bare `check("...")` inside
    `fault/mod.rs` tests exercises the registry, it is not an injection site.
    """
    sites: dict[str, list[str]] = {}
    for p in rust_files(root):
        if "tests" in p.parts:
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            for m in re.finditer(r"fault::(?:act|check)\(\s*\"([^\"]+)\"", line):
                sites.setdefault(m.group(1), []).append(f"{p}:{i}")
    return sites


def extract_fn_body(source: str, fn_name: str) -> str:
    """Brace-matched body of `fn <fn_name>` (best effort, comment-naive)."""
    m = re.search(rf"fn\s+{fn_name}\s*\(", source)
    if not m:
        return ""
    start = source.find("{", m.end())
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(source)):
        if source[i] == "{":
            depth += 1
        elif source[i] == "}":
            depth -= 1
            if depth == 0:
                return source[start : i + 1]
    return source[start:]


def parse_request_variants(msg: str) -> list[str]:
    m = re.search(r"pub enum Request\s*\{(.*?)\n\}", msg, re.DOTALL)
    if not m:
        return []
    variants = []
    for line in m.group(1).splitlines():
        vm = re.match(r"\s*([A-Z][A-Za-z0-9]*)\s*\{", line)
        if vm:
            variants.append(vm.group(1))
    return variants


def check(root: Path) -> list[str]:
    errors: list[str] = []
    obs_path = root / "rust" / "src" / "obs" / "mod.rs"
    msg_path = root / "rust" / "src" / "coordinator" / "msg.rs"
    lib_path = root / "rust" / "src" / "lib.rs"
    doc_path = root / "EXPERIMENTS.md"
    for p in (obs_path, msg_path, lib_path, doc_path):
        if not p.is_file():
            return [f"missing expected file: {p}"]
    obs_mod = obs_path.read_text()
    msg = msg_path.read_text()

    # R1/R2 — metric families, both directions.
    code_metrics = parse_registered_metrics(obs_mod)
    doc_metrics = parse_doc_table_metrics(doc_path.read_text())
    if not code_metrics:
        errors.append("R1: found no metric registrations in obs/mod.rs (parser drift?)")
    for name in sorted(code_metrics - doc_metrics):
        errors.append(
            f"R1 metrics-code-to-doc: `{name}` is registered in obs/mod.rs but "
            "appears in no EXPERIMENTS.md stable-name table row"
        )
    for name in sorted(doc_metrics - code_metrics):
        errors.append(
            f"R2 metrics-doc-to-code: `{name}` is documented in an EXPERIMENTS.md "
            "table but no code registers it"
        )

    # R3 — fault sites vs call-site literals.
    fault_sites = set(parse_str_array(obs_mod, "FAULT_SITES"))
    if not fault_sites:
        errors.append("R3: could not parse obs::FAULT_SITES (parser drift?)")
    call_sites = parse_fault_call_sites(root)
    for label, locs in sorted(call_sites.items()):
        if label not in fault_sites:
            errors.append(
                f"R3 fault-sites: call site label \"{label}\" ({locs[0]}) is not in "
                "obs::FAULT_SITES — its firings would land in the `other` series"
            )
    for label in sorted(fault_sites - set(call_sites) - {"other"}):
        errors.append(
            f"R3 fault-sites: obs::FAULT_SITES lists \"{label}\" but no "
            "fault::act/check call site uses it"
        )

    # R4 — Request variant exhaustiveness vs op tables and fuses_with.
    variants = parse_request_variants(msg)
    if not variants:
        errors.append("R4: could not parse `pub enum Request` (parser drift?)")
    ops = parse_str_array(obs_mod, "OPS")
    op_name_body = extract_fn_body(msg, "op_name")
    covered_ops = set()
    for v in variants:
        arm = re.search(rf"Request::{v}\s*{{[^}}]*}}\s*=>\s*\"([a-z_]+)\"", op_name_body)
        if not arm:
            errors.append(
                f"R4 request-variants: variant `{v}` has no arm in Request::op_name"
            )
            continue
        op = arm.group(1)
        covered_ops.add(op)
        if op not in ops:
            errors.append(
                f"R4 request-variants: op_name maps `{v}` to \"{op}\", which is "
                "missing from obs::OPS"
            )
    for op in ops:
        if op != "other" and op not in covered_ops:
            errors.append(
                f"R4 request-variants: obs::OPS lists \"{op}\" but no Request "
                "variant produces it"
            )
    fuses_body = extract_fn_body(msg, "fuses_with")
    if fuses_body and not re.search(r"\n\s*_\s*=>", fuses_body):
        errors.append(
            "R4 request-variants: fuses_with lost its wildcard arm — new variants "
            "would no longer default to non-fusing"
        )

    # R5 — ordering justification comments.
    for p in rust_files(root):
        lines = p.read_text().splitlines()
        for i, line in enumerate(lines):
            stripped = line.strip()
            if "Ordering::" not in line or stripped.startswith("//"):
                continue
            window = lines[max(0, i - ORDERING_COMMENT_WINDOW) : i + 1]
            if not any("// ordering:" in w for w in window):
                errors.append(
                    f"R5 ordering-comments: {p}:{i + 1} uses Ordering:: without a "
                    "`// ordering:` justification comment"
                )

    # R6 — lint attributes present.
    lib = lib_path.read_text()
    for attr in ("#![forbid(unsafe_code)]", "#![deny(unreachable_pub)]"):
        if attr not in lib:
            errors.append(f"R6 forbid-unsafe: rust/src/lib.rs lost `{attr}`")
    return errors


def run(root: Path) -> int:
    errors = check(root)
    if errors:
        for e in errors:
            print(f"lint_invariants: {e}", file=sys.stderr)
        print(f"lint_invariants: FAILED ({len(errors)} violation(s))", file=sys.stderr)
        return 1
    print("lint_invariants: OK (R1-R6 clean)")
    return 0


# ---------------------------------------------------------------------------
# self-test: inject each drift class, assert detection
# ---------------------------------------------------------------------------


def copy_tree(src_root: Path, dst_root: Path) -> None:
    for rel in ("rust/src", "rust/tests"):
        shutil.copytree(
            src_root / rel,
            dst_root / rel,
            ignore=shutil.ignore_patterns("vendor", "target"),
        )
    shutil.copy(src_root / "EXPERIMENTS.md", dst_root / "EXPERIMENTS.md")


def mutate(path: Path, old: str, new: str, *, append: bool = False) -> None:
    text = path.read_text()
    if append:
        path.write_text(text + new)
        return
    assert old in text, f"self-test fixture drift: {old!r} not found in {path}"
    path.write_text(text.replace(old, new, 1))


def self_test(repo_root: Path) -> int:
    cases = [
        (
            "R1 unregistered-in-docs metric",
            "rust/src/obs/mod.rs",
            lambda p: mutate(
                p,
                'reg.counter(\n            "fcs_retries_total"',
                'reg.counter(\n            "fcs_bogus_total"',
            ),
            "R1",
        ),
        (
            "R2 phantom doc metric",
            "EXPERIMENTS.md",
            lambda p: mutate(
                p,
                "",
                "\n| `fcs_phantom_total` | counter | — | does not exist |\n",
                append=True,
            ),
            "R2",
        ),
        (
            "R3 unknown fault-site label",
            "rust/src/obs/exporter.rs",
            lambda p: mutate(
                p, 'crate::fault::check("exporter")', 'crate::fault::check("exporterr")'
            ),
            "R3",
        ),
        (
            "R4 uncovered Request variant",
            "rust/src/coordinator/msg.rs",
            lambda p: mutate(
                p,
                "pub enum Request {",
                "pub enum Request {\n    Bogus { marker: usize },",
            ),
            "R4",
        ),
        (
            "R5 stripped ordering comment",
            "rust/src/coordinator/retry.rs",
            lambda p: p.write_text(
                "\n".join(
                    l
                    for l in p.read_text().splitlines()
                    if "// ordering:" not in l
                )
                + "\n"
            ),
            "R5",
        ),
        (
            "R6 dropped forbid(unsafe_code)",
            "rust/src/lib.rs",
            lambda p: mutate(p, "#![forbid(unsafe_code)]", ""),
            "R6",
        ),
    ]
    failures = 0
    with tempfile.TemporaryDirectory(prefix="lint_inv_selftest_") as td:
        pristine = Path(td) / "pristine"
        copy_tree(repo_root, pristine)
        base_errors = check(pristine)
        if base_errors:
            print("self-test: pristine copy must lint clean, got:", file=sys.stderr)
            for e in base_errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        print("self-test: pristine copy lints clean")
        for name, rel, inject, want_rule in cases:
            case_root = Path(td) / want_rule
            copy_tree(repo_root, case_root)
            inject(case_root / rel)
            errors = check(case_root)
            hits = [e for e in errors if e.startswith(want_rule)]
            if hits:
                print(f"self-test: {name}: caught ({hits[0][:100]}...)")
            else:
                failures += 1
                print(
                    f"self-test: {name}: NOT CAUGHT (errors: {errors})",
                    file=sys.stderr,
                )
    if failures:
        print(f"self-test: FAILED ({failures} drift class(es) escaped)", file=sys.stderr)
        return 1
    print("self-test: OK — every drift class detected")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repository root (default: this script's parent's parent)",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="inject each drift class into a temp copy and assert detection",
    )
    args = ap.parse_args()
    if args.self_test:
        return self_test(args.root)
    return run(args.root)


if __name__ == "__main__":
    sys.exit(main())
