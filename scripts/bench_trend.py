#!/usr/bin/env python3
"""Bench trend guard.

Folds the quick-bench JSON emitted by `scripts/verify.sh` (the `bench-results`
CI artifact: `results/*.json` and/or `rust/results/*.json`) into one
`BENCH_pr<N>.json` snapshot at the repo root — seeding the bench trajectory —
and fails (exit 2) on a >20% regression against the newest committed
`BENCH_pr*.json` baseline once one exists.

Metric extraction is generic so new bench rows join the trajectory for free:

* every numeric field named `secs*`/`*_secs` is a lower-is-better timing;
* every numeric field named `speedup*` is a higher-is-better ratio;
* rows are identified by their source file, `path` field, and any of the
  qualifier fields (rank, n, lanes, batch, d_reps, j, width, shards)
  present — `width` qualifies the coordinator fused-flight flood rows
  (`coord_flood`), `shards` the sharded-merge rows (`shard_merge`), each
  gated per shard count.

Usage:
    scripts/bench_trend.py [--results DIR ...] [--out BENCH_pr8.json]
                           [--threshold 0.20] [--soft]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

QUALIFIERS = ("rank", "n", "lanes", "batch", "d_reps", "j", "width", "shards")
TIMING_RE = re.compile(r"(^secs|_secs$)")
SPEEDUP_RE = re.compile(r"^speedup")


def record_id(source: str, row: dict) -> str:
    parts = [source]
    path = row.get("path")
    if isinstance(path, str):
        parts.append(path)
    for q in QUALIFIERS:
        v = row.get(q)
        if isinstance(v, (int, float)):
            parts.append(f"{q}={v:g}")
    return ":".join(parts)


def extract_metrics(results_dirs: list[str]) -> dict[str, dict]:
    """metric id -> {"value": float, "better": "lower"|"higher"}"""
    metrics: dict[str, dict] = {}
    for d in results_dirs:
        for fp in sorted(glob.glob(os.path.join(d, "*.json"))):
            source = os.path.splitext(os.path.basename(fp))[0]
            try:
                with open(fp) as f:
                    rows = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                print(f"[bench-trend] skipping unreadable {fp}: {e}")
                continue
            if not isinstance(rows, list):
                rows = [rows]
            for row in rows:
                if not isinstance(row, dict):
                    continue
                rid = record_id(source, row)
                for key, val in row.items():
                    if not isinstance(val, (int, float)):
                        continue
                    if TIMING_RE.search(key):
                        better = "lower"
                    elif SPEEDUP_RE.search(key):
                        better = "higher"
                    else:
                        continue
                    metrics[f"{rid}:{key}"] = {"value": float(val), "better": better}
    return metrics


def newest_baseline(repo_root: str) -> str | None:
    """The committed BENCH_pr<N>.json with the highest N — including the
    file this run is about to overwrite (its committed content IS the
    baseline), so the gate arms without bumping --out every PR."""
    best, best_n = None, -1
    for fp in glob.glob(os.path.join(repo_root, "BENCH_pr*.json")):
        m = re.match(r"BENCH_pr(\d+)\.json$", os.path.basename(fp))
        if m and int(m.group(1)) > best_n:
            best, best_n = fp, int(m.group(1))
    return best


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--results",
        nargs="*",
        default=["results", "rust/results"],
        help="directories holding the bench JSON (default: results rust/results)",
    )
    ap.add_argument("--out", default="BENCH_pr8.json", help="snapshot file at the repo root")
    ap.add_argument("--threshold", type=float, default=0.20, help="regression gate (fraction)")
    ap.add_argument("--soft", action="store_true", help="report regressions but exit 0")
    args = ap.parse_args()

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    results_dirs = [d if os.path.isabs(d) else os.path.join(repo_root, d) for d in args.results]
    metrics = extract_metrics(results_dirs)
    if not metrics:
        print("[bench-trend] no bench results found — nothing to snapshot")
        return 0

    # Read the baseline BEFORE overwriting the snapshot: when --out names the
    # already-committed file, its committed content is the baseline.
    baseline_path = newest_baseline(repo_root)
    baseline = {}
    if baseline_path is not None:
        with open(baseline_path) as f:
            baseline = json.load(f).get("metrics", {})

    out_path = os.path.join(repo_root, os.path.basename(args.out))
    snapshot = {"metrics": metrics}
    with open(out_path, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[bench-trend] wrote {out_path} ({len(metrics)} metrics)")

    if baseline_path is None:
        print("[bench-trend] no BENCH_pr*.json baseline yet — snapshot seeds the trajectory")
        return 0
    regressions = []
    compared = 0
    for mid, cur in metrics.items():
        base = baseline.get(mid)
        if not base:
            continue
        compared += 1
        old, new = float(base["value"]), float(cur["value"])
        if old <= 0.0:
            continue
        if cur["better"] == "lower":
            ratio = new / old
        else:
            ratio = old / new if new > 0.0 else float("inf")
        if ratio > 1.0 + args.threshold:
            regressions.append((mid, old, new, ratio))
    print(
        f"[bench-trend] compared {compared} shared metrics against "
        f"{os.path.basename(baseline_path)}"
    )
    for mid, old, new, ratio in regressions:
        print(f"[bench-trend] REGRESSION {mid}: {old:g} -> {new:g} ({(ratio - 1) * 100:.0f}% worse)")
    if regressions and not args.soft:
        print(f"[bench-trend] FAIL: {len(regressions)} metric(s) regressed >{args.threshold:.0%}")
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
