#!/usr/bin/env bash
# Tier-1 verification + bench bit-rot guard.
#
#   scripts/verify.sh          # build, format check, tests, quick benches
#   scripts/verify.sh --fast   # skip the bench smoke pass
#
# Benches are self-harnessed binaries (harness = false); FCS_BENCH_QUICK=1
# shrinks every sweep so each one finishes in seconds. Running them here
# guarantees they keep compiling *and* executing as the library evolves.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== invariant lint (self-test + repo scan) =="
# Static drift gate: stable metric names ↔ EXPERIMENTS.md tables, failpoint
# site labels ↔ call sites, Request variant exhaustiveness, atomic-ordering
# justification comments, forbid(unsafe_code). The self-test proves each
# drift class is actually detectable before the clean run is trusted.
python3 scripts/lint_invariants.py --self-test
python3 scripts/lint_invariants.py

echo "== cargo fmt --check =="
# Advisory: the offline image may carry a different rustfmt (or none); style
# drift should be visible in CI logs but must not mask real build failures.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all --check || echo "WARN: rustfmt reported differences (non-fatal)"
else
    echo "rustfmt unavailable; skipping"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== merge conformance + linearity suites =="
# Explicit re-runs of the sharded-merge contract (also covered by the full
# `cargo test` above): the bit-identity conformance suite and the qcheck
# linearity/associativity properties in sketch::merge. Named here so a CI
# log grep shows the merge≡whole gate ran, and so a local
# `scripts/verify.sh` failure points straight at the suite.
cargo test -q --test merge_conformance
cargo test -q --lib sketch::merge::

echo "== resilience suites (deadlines + failpoints chaos) =="
# Deadline/admission/retry semantics run in tier-1 above; the chaos suite
# needs the failpoints feature (compiled out of default builds), so it gets
# its own pass here along with the fault-registry unit tests. Named for the
# same reason as the merge gate: a log grep must show the overload-resilience
# contracts ran.
cargo test -q --test deadlines
cargo test -q -p fcs --features failpoints --test chaos
cargo test -q -p fcs --features failpoints --lib fault::

if [[ "${1:-}" != "--fast" ]]; then
    echo "== bench smoke (FCS_BENCH_QUICK=1) =="
    for bench in perf_hotpath ablation_hash fig1_rtpm_synthetic fig2_watercolors \
                 fig3_buddha fig5_kronecker fig6_contraction table1_complexity \
                 table2_hcs_vs_fcs table3_als table4_trn; do
        echo "-- bench: ${bench}"
        FCS_BENCH_QUICK=1 cargo bench --bench "${bench}"
    done
fi

echo "verify: OK"
