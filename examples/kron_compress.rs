//! Kronecker-product compression (the §4.3.1 workload as an example):
//! compress `A ⊗ B` with CS / HCS / FCS, decompress, compare error, speed,
//! and hash memory.
//!
//! ```sh
//! cargo run --release --example kron_compress -- --cr 4
//! ```

use fcs::compress::{Codec, KronCodec};
use fcs::linalg::Matrix;
use fcs::util::cli::Args;
use fcs::util::prng::Rng;

fn main() {
    let args = Args::from_env();
    let cr = args.get_f64("cr", 4.0);
    let d = args.get_usize("d", 20);

    let mut rng = Rng::seed_from_u64(11);
    let a = Matrix::from_data(30, 40, rng.uniform_vec(1200, -5.0, 5.0));
    let b = Matrix::from_data(40, 50, rng.uniform_vec(2000, -5.0, 5.0));
    println!(
        "A ∈ R^{{30×40}}, B ∈ R^{{40×50}}  ⇒  A⊗B ∈ R^{{1200×2000}} \
         ({} entries), CR {cr}, D {d}\n",
        1200 * 2000
    );

    for codec in [Codec::Cs, Codec::Hcs, Codec::Fcs] {
        let stats = KronCodec::evaluate(codec, &a, &b, cr, d, &mut rng);
        println!(
            "{:<4} sketch_len {:>8}  compress {:>9}  decompress {:>9}  \
             rel_err {:.4}  hash {:>10} B",
            stats.codec,
            stats.sketch_len,
            fcs::bench::fmt_secs(stats.compress_secs),
            fcs::bench::fmt_secs(stats.decompress_secs),
            stats.rel_error,
            stats.hash_bytes
        );
    }
    println!(
        "\nFCS never materializes A⊗B (it convolves the two matrix sketches)\n\
         and stores only the four short per-mode hash tables."
    );
}
