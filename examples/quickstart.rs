//! Quickstart: sketch a tensor four ways, estimate a contraction, and see
//! the paper's trade-offs in thirty lines of API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fcs::sketch::{build_equalized, ContractionEstimator, Method};
use fcs::tensor::{t_uuu, CpTensor};
use fcs::util::prng::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(7);

    // A noisy low-rank symmetric tensor T ∈ R^{60×60×60} (rank 5).
    let dim = 60;
    let cp = CpTensor::random_orthogonal_symmetric(&mut rng, dim, 5, 3);
    let mut t = cp.to_dense();
    t.add_noise(&mut rng, 0.01);

    // A unit query vector.
    let mut u = rng.normal_vec(dim);
    fcs::linalg::normalize(&mut u);
    let truth = t_uuu(&t, &u);
    println!("exact  T(u,u,u)            = {truth:+.6}");

    // Estimate it with every sketch at hash length J = 2000, D = 6.
    let (j, d) = (2000, 6);
    for method in [Method::Cs, Method::Ts, Method::Hcs, Method::Fcs] {
        let jm = if method == Method::Hcs { 14 } else { j }; // HCS: per-mode J
        let est = method.build(&t, d, jm, &mut rng);
        let got = est.t_uuu(&u);
        println!(
            "{:5}  T(u,u,u) ≈ {got:+.6}   (|err| {:.2e}, hash memory {} B)",
            est.name(),
            (got - truth).abs(),
            est.hash_bytes()
        );
    }

    // The paper's headline: under *equalized* hashes, FCS beats TS.
    let (ts, fcs) = build_equalized(&t, d, j, &mut rng);
    let (e_ts, e_fcs) = (ts.t_uuu(&u), fcs.t_uuu(&u));
    println!("\nequalized hashes: |TS err| = {:.3e}, |FCS err| = {:.3e}",
        (e_ts - truth).abs(), (e_fcs - truth).abs());
    println!("(Proposition 1: FCS has no circular-wraparound collisions, so it");
    println!(" is at least as accurate as TS given the same hash draws.)");
}
