//! The L3 coordinator in action: start the sketch service (XLA-backed when
//! artifacts exist), hammer it from concurrent clients, print the serving
//! stats (throughput, latency percentiles, batch fill, rejections).
//!
//! ```sh
//! make artifacts && cargo run --release --example sketch_service -- \
//!     --clients 8 --requests 500
//! ```

use fcs::coordinator::{Request, Response, Service, ServiceConfig, ServiceError, SketchMethod};
use fcs::tensor::Tensor;
use fcs::util::cli::Args;
use fcs::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let clients = args.get_usize("clients", 8);
    let per_client = args.get_usize("requests", 500);

    let runtime = match fcs::runtime::spawn_runtime(None) {
        Ok(rt) => {
            println!("XLA runtime up: artifacts at {}", rt.dir.display());
            Some(rt)
        }
        Err(e) => {
            println!("no artifacts ({e}); running on the pure-Rust path");
            None
        }
    };
    let svc = Service::start(ServiceConfig::default(), runtime)?;
    let h = svc.handle();
    println!(
        "service up: cs_vec dim {} → {}, {} clients × {} requests",
        h.cs_in_dim, h.cs_out_dim, clients, per_client
    );

    let sw = fcs::util::timing::Stopwatch::start();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(c as u64);
                let mut done = 0usize;
                let mut busy = 0usize;
                for i in 0..per_client {
                    // Mix of ops: mostly batched cs_vec, some tensor sketches
                    // and estimates through the worker pool.
                    let req = match i % 10 {
                        0 => {
                            let t = Tensor::randn(&mut rng, &[8, 8, 8]);
                            Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 64 }
                        }
                        1 => {
                            let a = Tensor::randn(&mut rng, &[6, 6, 6]);
                            Request::InnerEstimate {
                                b: a.clone(),
                                a,
                                method: SketchMethod::Fcs,
                                j: 512,
                                d: 5,
                            }
                        }
                        _ => Request::CsVec { x: rng.normal_vec(h.cs_in_dim) },
                    };
                    loop {
                        match h.call(req.clone()) {
                            Ok(Response::Sketch(_)) | Ok(Response::Scalar(_)) => {
                                done += 1;
                                break;
                            }
                            Err(ServiceError::Busy) => {
                                busy += 1;
                                std::thread::yield_now();
                            }
                            Err(e) => panic!("{e}"),
                        }
                    }
                }
                (done, busy)
            })
        })
        .collect();
    let mut total = 0;
    let mut retries = 0;
    for t in threads {
        let (d, b) = t.join().unwrap();
        total += d;
        retries += b;
    }
    let secs = sw.elapsed_secs();

    let report = svc.stats();
    println!("\n{total} requests served in {secs:.2}s → {:.0} req/s ({retries} busy-retries)",
        total as f64 / secs);
    println!("batches: {} (mean fill {:.1}/32), rejected: {}", report.batches,
        report.mean_batch_fill, report.rejected_busy);
    for op in &report.per_op {
        println!(
            "  {:<15} n={:<6} p50 {:>8.0}µs  p95 {:>8.0}µs  p99 {:>8.0}µs",
            op.op, op.completed, op.p50_us, op.p95_us, op.p99_us
        );
    }
    svc.shutdown();
    Ok(())
}
