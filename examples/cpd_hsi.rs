//! Sketched CP decomposition of a hyperspectral-like cube (the Fig. 2
//! workload at example scale): FCS-RTPM vs TS-RTPM vs plain, reporting
//! PSNR and time.
//!
//! ```sh
//! cargo run --release --example cpd_hsi -- --size 128 --rank 10 --j 4000
//! ```

use fcs::cpd::{rtpm_asymmetric, RtpmConfig};
use fcs::data::{hsi_cube, psnr};
use fcs::sketch::{build_equalized, ContractionEstimator, PlainEstimator};
use fcs::util::cli::Args;
use fcs::util::prng::Rng;
use fcs::util::timing::Stopwatch;

fn main() {
    let args = Args::from_env();
    let size = args.get_usize("size", 128);
    let bands = args.get_usize("bands", 31);
    let rank = args.get_usize("rank", 10);
    let j = args.get_usize("j", 4000);
    let d = args.get_usize("d", 10);

    let mut rng = Rng::seed_from_u64(42);
    println!("generating {size}×{size}×{bands} HSI-like cube…");
    let t = hsi_cube(&mut rng, size, size, bands, 8, 0.01);
    let shape = [size, size, bands];
    let cfg = RtpmConfig { rank, n_init: 4, n_iter: 10, seed: 5 };

    // plain
    let sw = Stopwatch::start();
    let mut plain = PlainEstimator::new(t.clone());
    let cp = rtpm_asymmetric(&mut plain, &shape, &cfg);
    let plain_secs = sw.elapsed_secs();
    let plain_psnr = psnr(&cp.to_dense(), &t, 1.0);
    println!("plain RTPM: PSNR {plain_psnr:.2} dB in {plain_secs:.1}s");

    // TS and FCS under equalized hashes
    let (mut ts, mut fcs) = build_equalized(&t, d, j, &mut rng);
    for (name, est) in [
        ("TS ", &mut ts as &mut dyn ContractionEstimator),
        ("FCS", &mut fcs as &mut dyn ContractionEstimator),
    ] {
        let sw = Stopwatch::start();
        let cp = rtpm_asymmetric(est, &shape, &cfg);
        let secs = sw.elapsed_secs();
        println!(
            "{name} RTPM (J={j}, D={d}): PSNR {:.2} dB in {secs:.1}s  \
             ({:.1}× plain speed)",
            psnr(&cp.to_dense(), &t, 1.0),
            plain_secs / secs
        );
    }
    println!("\nexpected: FCS PSNR ≥ TS PSNR, both well above 20 dB and faster than plain.");
}
