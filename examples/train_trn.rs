//! **End-to-end driver** (DESIGN.md §4, EXPERIMENTS.md §E2E): train the
//! sketched CP tensor-regression network on the FMNIST-like dataset, fully
//! through the three-layer stack — Rust owns the training loop and data,
//! the AOT-compiled XLA train-step (JAX fwd/bwd calling the Pallas
//! count-sketch kernel) does the math. Python is not running.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_trn -- \
//!     --method fcs --cr 20 --steps 300
//! ```

use fcs::runtime::spawn_runtime;
use fcs::trn::{train_and_eval, TrnMethod, TrnRunConfig};
use fcs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let method = TrnMethod::parse(&args.get_or("method", "fcs"))
        .expect("--method must be cs|ts|fcs");
    let cr_tag = args.get_or("cr", "20").replace('.', "p");
    let steps = args.get_usize("steps", 300);

    let rt = spawn_runtime(None)?;
    println!(
        "artifacts: {} ({} compiled graphs available)",
        rt.dir.display(),
        rt.manifest().entries.len()
    );

    let cfg = TrnRunConfig {
        method,
        cr_tag: cr_tag.clone(),
        steps,
        lr: args.get_f64("lr", 0.05) as f32,
        train_size: args.get_usize("train-size", 6400),
        test_size: args.get_usize("test-size", 1024),
        seed: args.get_usize("seed", 1234) as u64,
        log_every: args.get_usize("log-every", 20),
    };
    println!(
        "training sketched CP-TRL: method={} CR tag={} steps={} lr={}",
        method.name(),
        cr_tag,
        steps,
        cfg.lr
    );
    let res = train_and_eval(&rt, &cfg)?;

    // Loss curve (downsampled ASCII log).
    println!("\nloss curve (every ~{} steps):", (res.losses.len() / 20).max(1));
    let stride = (res.losses.len() / 20).max(1);
    for (i, chunk) in res.losses.chunks(stride).enumerate() {
        let mean = chunk.iter().sum::<f64>() / chunk.len() as f64;
        let bars = ((mean / res.losses[0]).min(1.0) * 50.0) as usize;
        println!("  step {:4}  loss {:.4}  {}", i * stride, mean, "#".repeat(bars));
    }
    println!(
        "\nfinal loss {:.4} (from {:.4}); test accuracy {:.2}% (chance = 10%); \
         train time {:.1}s",
        res.losses.last().unwrap(),
        res.losses[0],
        res.accuracy * 100.0,
        res.train_secs
    );
    Ok(())
}
